"""Replicated serving fabric: a ``ReplicaRouter`` fronts K identical
``RetrievalEngine`` replicas behind the single-engine submit/drain/stats
API and layers on what one engine cannot give you:

* **Pipelined dispatch** — each replica is owned by one worker thread
  that keeps up to ``dispatch_depth`` batches in flight (JAX dispatch is
  async: the host pads and enqueues batch N+1 while the device still owns
  batch N), and partial batches dispatch once the oldest request has
  waited ``max_wait_ms`` — a trickle of traffic never stalls on a full
  bucket.
* **Health-checked failover** — a per-replica state machine (healthy ->
  suspect on straggler/failure strikes -> ejected) with half-open probe
  re-admission after an exponentially backed-off cooldown.  Work in
  flight on a dead replica is re-dispatched to a healthy one; a request
  is NEVER lost, and never answered twice.
* **Hedged dispatch** — a batch outstanding longer than the observed
  p99 job time (floored at ``hedge_floor_ms``) is re-issued to a second
  healthy replica; the first completion wins and the loser's results are
  suppressed by request id.
* **Load-adaptive degradation** — a watermark ladder on total queue
  depth: level 1 caps the batch k, level 2 additionally pins the pruned
  cascade to its cheapest calibrated rung (``RetrievalEngine``'s
  ``serve_fn_pinned`` route), level 3 sheds new work outright.  Every
  result served below full fidelity carries a ``Result.degraded`` tag,
  and recovery is hysteresis-damped (the level only drops after the
  depth has sat below the low watermark for ``recover_patience``
  consecutive scheduling passes) so the ladder cannot thrash.
* **Durable versioned mutation** (ISSUE 10) — a fabric built over a
  mutable catalogue (:meth:`for_seqrec_mutable`) takes mutations through
  ONE entry, :meth:`apply_mutations`: each op is appended to the
  ``CatalogueLog`` WAL *before* any replica applies it, then every
  replica worker replays the op batch between dispatches through the
  zero-recompile ``swap_head_state`` path — LSN-fenced, so duplicate
  delivery is idempotent and a sequence gap (a crashed replica) forces
  snapshot+replay recovery from the log.  Every ``Result`` carries the
  serving replica's applied-LSN watermark; a replica lagging the
  committed LSN past ``staleness_budget`` is deprioritised in
  eligibility and its results are tagged ``degraded="stale_catalogue"``;
  and a crashed/ejected replica must finish its catch-up replay before
  the health FSM will re-admit it to ``healthy``.

Threading model: each engine is touched by exactly ONE worker thread
(engines are not thread-safe); the scheduler — health bookkeeping, job
assignment, hedging, the ladder — runs entirely on the caller's thread
inside :meth:`pump` / :meth:`drain`.  The only cross-thread structures
are the per-replica job queues, the per-replica mutation queues and the
shared completion-event queue; catalogue application and the head swap
happen on the owning worker thread, never on the caller's.
"""
from __future__ import annotations

import collections
import queue
import threading
import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.core.mutation import MutableHeadState, apply_op
from repro.serving.engine import (InFlightBatch, MicroBatcher, Request,
                                  Result, RetrievalEngine)
from repro.training.fault_tolerance import ReplicaFaultPlan, SimulatedFailure

_STOP = object()

HEALTHY, SUSPECT, EJECTED, PROBING = "healthy", "suspect", "ejected", "probing"


@dataclass
class _Job:
    """One batch's worth of work as handed to a replica worker.  A hedge
    re-issue is a second ``_Job`` with the same ``job_id`` (duplicate
    results are suppressed by request id at delivery)."""
    job_id: int
    requests: List[Request]
    k_cap: Optional[int]
    rung_pin: bool
    replica: int
    hedged: bool = False


@dataclass
class _JobState:
    """Scheduler-side view of one logical job across all its copies."""
    requests: List[Request]
    k_cap: Optional[int]
    rung_pin: bool
    replica: int                      # replica of the primary copy
    copies: int = 1                   # live copies in flight
    hedged: bool = False
    attempts: int = 0                 # failed-and-redispatched count
    first_dispatch_t: float = 0.0


@dataclass
class _Event:
    kind: str                         # "done" | "fail"
    job: _Job
    results: List[Result]
    replica: int
    straggler: bool = False
    lsn: int = -1                     # replica's applied LSN at dispatch
    stale: bool = False               # lag exceeded the staleness budget


@dataclass
class ReplicaState:
    """Health state machine for one replica.  Transitions happen only on
    the scheduler thread:

    healthy --strikes>=suspect_after--> suspect
            --strikes>=eject_after-->   ejected  (in-flight work
                                                  re-dispatched on failure)
    ejected --cooldown elapsed-->       probing  (half-open: ONE job)
    probing --probe succeeds-->         healthy  (re-admitted, cooldown
                                                  reset)
            --probe fails-->            ejected  (cooldown doubles)
    """
    state: str = HEALTHY
    strikes: int = 0
    cooldown_ms: float = 100.0
    ejected_at: float = 0.0
    probe_outstanding: bool = False
    inflight: int = 0                 # jobs assigned, not yet resolved
    dispatched: int = 0
    completed: int = 0
    failures: int = 0
    stragglers: int = 0
    ejections: int = 0
    readmissions: int = 0


class ReplicaRouter:
    """Route requests across K ``RetrievalEngine`` replicas (same model,
    same compiled serving route) with failover, hedging and graceful
    degradation.  API mirrors the single engine: :meth:`submit`,
    :meth:`drain`, :meth:`stats`; :meth:`pump` runs one scheduling pass
    for callers driving their own loop.  Use as a context manager (or
    call :meth:`close`) to join the worker threads."""

    def __init__(self, engines: Sequence[RetrievalEngine], *,
                 dispatch_depth: int = 2,
                 max_batch: Optional[int] = None,
                 max_wait_ms: float = 2.0,
                 fault_plans: Optional[Dict[int, ReplicaFaultPlan]] = None,
                 suspect_after: int = 1, eject_after: int = 3,
                 cooldown_ms: float = 100.0,
                 hedge: bool = True, hedge_floor_ms: float = 50.0,
                 max_redispatch: Optional[int] = None,
                 degrade_high: int = 256, degrade_low: int = 64,
                 degrade_k_cap: Optional[int] = None,
                 degrade_patience: int = 1, recover_patience: int = 3,
                 replica_states: Optional[Sequence[MutableHeadState]] = None,
                 log: Optional[Any] = None,
                 staleness_budget: int = 0):
        if not engines:
            raise ValueError("need at least one replica engine")
        self.engines = list(engines)
        self.n_replicas = len(self.engines)
        self.dispatch_depth = max(1, dispatch_depth)
        mb = max_batch or min(e.batcher.max_batch for e in self.engines)
        self.batcher = MicroBatcher(max_batch=mb, max_wait_ms=max_wait_ms)
        self.fault_plans = dict(fault_plans or {})
        self.suspect_after = suspect_after
        self.eject_after = eject_after
        self.hedge_enabled = hedge and self.n_replicas > 1
        self.hedge_floor_ms = hedge_floor_ms
        self.max_redispatch = (2 * self.n_replicas if max_redispatch is None
                               else max_redispatch)
        self.degrade_high = degrade_high
        self.degrade_low = degrade_low
        self.degrade_k_cap = (degrade_k_cap if degrade_k_cap is not None
                              else min(e.k for e in self.engines))
        self.degrade_patience = max(1, degrade_patience)
        self.recover_patience = max(1, recover_patience)

        self.replicas = [ReplicaState(cooldown_ms=cooldown_ms)
                         for _ in range(self.n_replicas)]
        self._base_cooldown_ms = cooldown_ms
        self._queues: List[queue.Queue] = [queue.Queue()
                                           for _ in range(self.n_replicas)]
        self._events: queue.Queue = queue.Queue()
        self._dispatch_idx = [0] * self.n_replicas   # worker-local counters

        self._jobs: Dict[int, _JobState] = {}
        self._retry: collections.deque[_JobState] = collections.deque()
        self._next_job_id = 0
        self._expected: set = set()
        self._done_ids: set = set()
        self._completed: List[Result] = []
        self._latencies_ms: List[float] = []
        self._job_wall_ms: collections.deque = collections.deque(maxlen=512)

        self.level = 0
        self._over = self._under = 0
        self.degrade_events = 0
        self.recover_events = 0
        self.degraded_results: collections.Counter = collections.Counter()
        self.shed_load = 0
        self.hedges = 0
        self.hedge_wins = 0
        self.duplicates_suppressed = 0
        self.redispatched = 0

        # -- durable mutable catalogue (ISSUE 10) -----------------------
        self.mutable = replica_states is not None
        if self.mutable and len(replica_states) != self.n_replicas:
            raise ValueError(
                f"{len(replica_states)} replica states for "
                f"{self.n_replicas} engines — each replica owns exactly "
                "one MutableHeadState clone")
        if log is not None and not self.mutable:
            raise ValueError("a CatalogueLog needs mutable replicas "
                             "(replica_states / for_seqrec_mutable)")
        self._replica_states: List[Optional[MutableHeadState]] = \
            list(replica_states or [])
        self.log = log
        self.staleness_budget = max(0, int(staleness_budget))
        # The writer state is the scheduler-side authoritative catalogue:
        # apply_mutations validates + applies here first (WAL discipline
        # needs a validated op), and snapshots are cut from it.  A clone,
        # because replica 0's state is owned by its worker thread.
        self._writer_state = (self._replica_states[0].clone()
                              if self.mutable else None)
        self._committed_lsn = (log.lsn if (self.mutable and log is not None)
                               else 0)
        self._applied_lsn = [self._committed_lsn] * self.n_replicas
        self._mut_queues: List[queue.Queue] = [
            queue.Queue() for _ in range(self.n_replicas)]
        self._paused = [False] * self.n_replicas    # chaos: freeze catch-up
        self._needs_recovery = [False] * self.n_replicas
        self.stale_served = 0
        self.catchup_events = 0
        self.mutations_applied = 0
        if self.mutable and log is not None \
                and log.latest_snapshot_lsn() is None:
            # A log with no snapshot cannot recover (replay needs a base
            # state): cut the genesis snapshot at the current LSN.
            log.snapshot(self._writer_state)

        self._closed = False
        self._threads = [
            threading.Thread(target=self._worker, args=(rid,), daemon=True,
                             name=f"replica-{rid}")
            for rid in range(self.n_replicas)]
        for t in self._threads:
            t.start()

    @classmethod
    def for_seqrec(cls, params, cfg, *, n_replicas: int = 2, k: int = 10,
                   max_batch: int = 64, method: Optional[str] = None,
                   sharded_mesh=None, calibrate: Optional[bool] = None,
                   survival_stats: Optional[Sequence[int]] = None,
                   ladder=None, **router_kw) -> "ReplicaRouter":
        """Stand up K identical replicas of a seqrec serving engine.  The
        pruned route's slot-budget ladder is calibrated ONCE (on the
        first replica) and shared, so replicas compile byte-identical
        serve functions — which is what makes the healthy-path
        bit-parity guarantee hold across failover."""
        first = RetrievalEngine.for_seqrec(
            params, cfg, k=k, max_batch=max_batch, method=method,
            sharded_mesh=sharded_mesh, calibrate=calibrate,
            survival_stats=survival_stats, ladder=ladder)
        engines = [first]
        for _ in range(n_replicas - 1):
            engines.append(RetrievalEngine.for_seqrec(
                params, cfg, k=k, max_batch=max_batch, method=method,
                sharded_mesh=sharded_mesh, ladder=first.ladder,
                calibrate=False))
        return cls(engines, **router_kw)

    @classmethod
    def for_seqrec_mutable(cls, params, cfg, mstate, *,
                           n_replicas: int = 2, k: int = 10,
                           max_batch: int = 64,
                           calibrate: Optional[bool] = None,
                           survival_stats: Optional[Sequence[int]] = None,
                           ladder=None, log: Optional[Any] = None,
                           **router_kw) -> "ReplicaRouter":
        """K replicas over ONE logical mutable catalogue.  Each replica
        engine owns its own ``MutableHeadState`` clone (device arrays
        shared until a mutation forks them; host freelist/staleness
        copied) and replays the same LSN-ordered op stream, so replica
        states — and therefore untagged answers — stay bit-identical
        across the fleet.  The calibrated ladder is shared from the
        first replica exactly like :meth:`for_seqrec`.

        ``log`` (a ``serving.catalogue_log.CatalogueLog``) makes the
        stream durable: :meth:`apply_mutations` appends there first, and
        crashed replicas / a restarted router recover from it.  To stand
        a router back up after a crash::

            log = CatalogueLog(log_dir)           # truncates any torn tail
            state, lsn = log.recover()
            router = ReplicaRouter.for_seqrec_mutable(params, cfg, state,
                                                      log=log, ...)
        """
        states = [mstate] + [mstate.clone() for _ in range(n_replicas - 1)]
        first = RetrievalEngine.for_seqrec_mutable(
            params, cfg, states[0], k=k, max_batch=max_batch,
            calibrate=calibrate, survival_stats=survival_stats,
            ladder=ladder)
        engines = [first]
        for st in states[1:]:
            engines.append(RetrievalEngine.for_seqrec_mutable(
                params, cfg, st, k=k, max_batch=max_batch,
                ladder=first.ladder, calibrate=False))
        return cls(engines, replica_states=states, log=log, **router_kw)

    def warmup(self, ks: Sequence[int] = (), buckets: Sequence[int] = ()):
        """Synchronously compile the hot serve variants on EVERY replica
        (full-bucket batch at the engines' base k plus any extra ``ks`` /
        ``buckets``, and the rung-pinned route where present) before
        traffic arrives.  Cold AOT compiles serialise on a loaded host;
        without warmup the first batches straggle behind multi-second
        compiles, the hedger fires on compile noise, and a latency
        benchmark measures XLA, not serving."""
        for eng in self.engines:
            bks = set(buckets) | {self.batcher.max_batch}
            kks = {eng.batch_k([k]) for k in set(ks) | {eng.k}}
            for b in bks:
                bb = MicroBatcher.bucket(b, eng.batcher.max_batch)
                for kk in kks:
                    eng._variant(bb, kk)
                    if eng.has_pinned:
                        eng._variant(bb, kk, pinned=True)

    # ------------------------------------------------------------------
    # worker side (one thread per replica; the only code touching engines)
    # ------------------------------------------------------------------

    def _worker(self, rid: int):
        eng = self.engines[rid]
        plan = self.fault_plans.get(rid)
        q = self._queues[rid]
        inflight: collections.deque = collections.deque()
        while True:
            if self.mutable:
                # Catalogue catch-up BETWEEN dispatches, on the thread
                # that owns the engine: apply any pending op batches and
                # hot-swap the head (zero recompiles) before more work.
                self._apply_pending(rid, eng)
            job = None
            if len(inflight) < self.dispatch_depth:
                try:
                    # Block only when the pipeline is empty; with work in
                    # flight, poll so completions are not starved.
                    job = q.get(block=not inflight, timeout=0.02)
                except queue.Empty:
                    job = None
            if job is _STOP:
                while inflight:           # never abandon in-flight work
                    self._finish(rid, *inflight.popleft())
                break
            if job is not None:
                if self.mutable:
                    # A job may have queued behind newer mutations:
                    # re-drain so the dispatch serves the freshest state
                    # this replica can reach.
                    self._apply_pending(rid, eng)
                self._start(rid, eng, plan, job, inflight)
            elif inflight:
                self._finish(rid, *inflight.popleft())

    def _apply_pending(self, rid: int, eng: RetrievalEngine):
        """Drain this replica's mutation queue (worker thread only).

        LSN fencing makes delivery idempotent and gap-safe: an op at or
        below the applied watermark is a duplicate (skipped); an op more
        than one ahead means this replica missed a delta — only possible
        after a (simulated) crash — and forces snapshot+replay recovery
        from the durable log.  A "crash" marker drops the in-memory
        state outright; the very next pass recovers it.  The engine sees
        one ``swap_head_state`` per drain, not per op."""
        if self._paused[rid]:
            return
        q = self._mut_queues[rid]
        st = self._replica_states[rid]
        applied = self._applied_lsn[rid]
        dirty = False
        while True:
            try:
                kind, payload = q.get_nowait()
            except queue.Empty:
                break
            if kind == "crash":
                st, applied, dirty = None, -1, False
                continue
            for lsn, op in payload:
                if st is None or lsn > applied + 1:
                    st, applied = self._recover_replica(rid)
                    dirty = True
                if lsn <= applied:
                    continue              # duplicate / already recovered
                if lsn > applied + 1:     # still gapped after recovery:
                    raise RuntimeError(   # the log lost acked ops
                        f"replica {rid}: op lsn {lsn} but recovered log "
                        f"ends at {applied} — durable log is missing "
                        "committed entries")
                apply_op(st, op)
                applied = lsn
                dirty = True
        if st is None:                    # crashed with an empty tail
            st, applied = self._recover_replica(rid)
            dirty = True
        if dirty:
            self._replica_states[rid] = st
            eng.swap_head_state(st)
        self._applied_lsn[rid] = applied

    def _recover_replica(self, rid: int):
        """Snapshot+replay from the durable log (worker thread).  Reads
        never truncate and tolerate a concurrent append's torn tail; any
        ops past what the read sees are still queued behind this drain
        and land through the normal LSN-fenced path."""
        if self.log is None:
            raise RuntimeError(
                f"replica {rid} lost its catalogue state and no durable "
                "log is attached; build the router with a CatalogueLog")
        # Force the committed prefix onto disk first: recover() reads the
        # file, and appends inside the fsync window would otherwise be
        # invisible — the replica would land BELOW the committed LSN with
        # the missing batch already consumed from its queue.  (The
        # buffered writer is lock-protected, so syncing from a worker
        # thread is safe against a concurrent append; a crashed writer is
        # left alone — its durable prefix is already fsynced.)
        if not self.log.read_only and not self.log._crashed:
            self.log.sync()
        st, lsn = self.log.recover()
        self.catchup_events += 1
        self._needs_recovery[rid] = False
        return st, lsn

    def _start(self, rid: int, eng: RetrievalEngine,
               plan: Optional[ReplicaFaultPlan], job: _Job,
               inflight: collections.deque):
        """Prepare + asynchronously launch one job; chaos (the replica
        fault plan) is consulted on this replica's own dispatch counter,
        so a schedule replays identically however the router interleaves
        replicas."""
        d_idx = self._dispatch_idx[rid]
        self._dispatch_idx[rid] = d_idx + 1
        # Catalogue watermark at dispatch: the results of this job were
        # computed against exactly this LSN.  Staleness is judged here,
        # not at delivery — a result is stale iff the state it was
        # SERVED from lagged, however long delivery takes.
        lsn = self._applied_lsn[rid] if self.mutable else -1
        stale = (self.mutable
                 and self._committed_lsn - lsn > self.staleness_budget)
        try:
            extra = plan.check(d_idx) if plan is not None else 0.0
            shed, prep = eng.prepare(job.requests, k_cap=job.k_cap,
                                     rung_pin=job.rung_pin)
            if prep is None:
                self._events.put(_Event("done", job, shed, rid,
                                        lsn=lsn, stale=stale))
                return
            if extra:
                time.sleep(extra)         # straggling replica
            inflight.append((job, eng.launch(prep), shed, lsn, stale))
        except SimulatedFailure:
            self._events.put(_Event("fail", job, [], rid))

    def _finish(self, rid: int, job: _Job, inf: InFlightBatch,
                shed: List[Result], lsn: int = -1, stale: bool = False):
        try:
            res = self.engines[rid].complete(inf)
        except SimulatedFailure:
            # Deadline sheds from prepare() are still final answers — only
            # the dispatched rows are retried elsewhere.
            self._events.put(_Event("fail", job, shed, rid))
        else:
            self._events.put(_Event("done", job, shed + res, rid,
                                    straggler=inf.straggler, lsn=lsn,
                                    stale=stale))

    # ------------------------------------------------------------------
    # scheduler side (caller thread only)
    # ------------------------------------------------------------------

    def apply_mutations(self, ops) -> int:
        """The single durable entry for catalogue mutations (caller
        thread).  WAL discipline, in order per op: validate + apply to
        the writer state (an invalid op raises BEFORE anything becomes
        durable), append to the log, and only then fan the batch out to
        the replica workers — so no replica can ever apply an op the log
        does not hold.  Returns the committed LSN.

        A ``SimulatedFailure`` out of the log append is the torn-record
        chaos experiment: the writer "crashed" mid-append.  The durable
        prefix is still consistent (everything already fanned out is on
        disk); close this router and stand a new one up from
        ``CatalogueLog.recover()``."""
        if not self.mutable:
            raise ValueError(
                "router fronts an immutable catalogue; build it with "
                "for_seqrec_mutable (or replica_states=) to mutate")
        entries = []
        try:
            for op in ops:
                apply_op(self._writer_state, op)
                lsn = (self.log.append(op) if self.log is not None
                       else self._committed_lsn + len(entries) + 1)
                entries.append((lsn, op))
        finally:
            if entries:
                self._committed_lsn = entries[-1][0]
                self.mutations_applied += len(entries)
                for q in self._mut_queues:
                    q.put(("ops", entries))
        if self.log is not None:
            self.log.maybe_snapshot(self._writer_state)
        return self._committed_lsn

    def crash_replica(self, rid: int):
        """Chaos hook: simulate process death of one replica.  Its
        in-memory catalogue state is dropped (a "crash" marker its
        worker honours before the next dispatch), it is ejected from
        rotation, and re-admission is gated: the health FSM keeps it out
        of ``healthy`` until it has recovered snapshot+tail from the
        durable log and caught up within the staleness budget."""
        if not self.mutable:
            raise ValueError("crash_replica needs a mutable fabric")
        rs = self.replicas[rid]
        if rs.state != EJECTED:
            rs.state = EJECTED
            rs.ejected_at = time.monotonic()
            rs.ejections += 1
        rs.strikes = max(rs.strikes, self.eject_after)
        self._needs_recovery[rid] = True
        self._mut_queues[rid].put(("crash", None))

    def pause_mutations(self, rid: int):
        """Chaos hook: freeze one replica's catalogue catch-up (its
        worker stops draining the mutation queue), so it serves an
        ever-staler state — the deterministic way to exercise the
        staleness budget, the ``stale_catalogue`` tag and the catch-up
        re-admission gate."""
        self._paused[rid] = True

    def resume_mutations(self, rid: int):
        self._paused[rid] = False

    def _lag(self, rid: int) -> int:
        applied = self._applied_lsn[rid]
        if applied < 0:                   # crashed, recovery pending
            return self._committed_lsn + 1
        return max(0, self._committed_lsn - applied)

    def submit(self, req: Request):
        """Accept a request (or, at ladder level 3, shed it immediately
        with a ``load_shed``-tagged Result — the client still gets
        exactly one answer)."""
        self._expected.add(req.request_id)
        if self.level >= 3:
            now = time.monotonic()
            lat = (now - req.arrival) * 1e3
            self.shed_load += 1
            self.degraded_results["load_shed"] += 1
            self._done_ids.add(req.request_id)
            self._latencies_ms.append(lat)
            self._completed.append(Result(
                req.request_id, np.empty(0, np.int32),
                np.empty(0, np.float32), lat, shed=True,
                degraded="load_shed"))
            return
        self.batcher.submit(req)

    def pump(self, block: bool = False, timeout: float = 0.05) -> bool:
        """One scheduling pass: absorb completion events, update the
        degradation ladder and replica health, assign ready batches,
        issue hedges.  Returns True if any event was processed."""
        progressed = False
        first = True
        while True:
            try:
                ev = self._events.get(block=block and first, timeout=timeout)
            except queue.Empty:
                break
            first = False
            progressed = True
            self._handle(ev)
        self._update_load()
        self._update_health()
        self._schedule()
        if self.hedge_enabled:
            self._maybe_hedge()
        return progressed

    def drain(self, timeout_s: float = 120.0) -> List[Result]:
        """Pump until every submitted request has exactly one Result; a
        stall (no event for ``timeout_s``) raises rather than hanging —
        by construction (failover + forced probes) that only fires on a
        genuinely wedged fabric."""
        last_progress = time.monotonic()
        while self._expected - self._done_ids:
            if self.pump(block=True, timeout=0.05):
                last_progress = time.monotonic()
            elif time.monotonic() - last_progress > timeout_s:
                missing = sorted(self._expected - self._done_ids)[:10]
                raise RuntimeError(
                    f"router stalled; undelivered request ids {missing}...")
        self.pump()                       # absorb trailing duplicates
        out, self._completed = self._completed, []
        return out

    def close(self):
        if self._closed:
            return
        self._closed = True
        for q in self._queues:
            q.put(_STOP)
        for t in self._threads:
            t.join(timeout=30.0)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # -- event handling -------------------------------------------------

    def _handle(self, ev: _Event):
        rs = self.replicas[ev.replica]
        rs.inflight = max(0, rs.inflight - 1)
        st = self._jobs.get(ev.job.job_id)
        if rs.probe_outstanding:
            rs.probe_outstanding = False
        delivered_new = False
        for r in ev.results:
            if r.request_id in self._done_ids:
                self.duplicates_suppressed += 1
                continue
            delivered_new = True
            self._done_ids.add(r.request_id)
            if not r.shed:
                r.replica = ev.replica
                r.hedged = bool(st and st.hedged)
                if self.mutable:
                    r.lsn = ev.lsn
                    if ev.stale:
                        # Served from a catalogue older than the budget
                        # allows: still a correct answer *for its LSN*,
                        # but no longer the exactness contract's answer.
                        self.stale_served += 1
                        r.degraded = (f"{r.degraded}+stale_catalogue"
                                      if r.degraded else "stale_catalogue")
            if r.degraded:
                self.degraded_results[r.degraded] += 1
            self._latencies_ms.append(r.latency_ms)
            self._completed.append(r)
        if ev.kind == "done":
            rs.completed += 1
            if st is not None and st.first_dispatch_t:
                self._job_wall_ms.append(
                    (time.monotonic() - st.first_dispatch_t) * 1e3)
            if ev.job.hedged and delivered_new:
                self.hedge_wins += 1
            if ev.straggler:
                rs.stragglers += 1
                self._strike(ev.replica)
            else:
                self._ok(ev.replica)
        else:
            rs.failures += 1
            self._strike(ev.replica)
        if st is None:
            return
        st.copies -= 1
        if st.copies > 0:
            return
        undone = [r for r in st.requests
                  if r.request_id not in self._done_ids]
        if not undone:
            del self._jobs[ev.job.job_id]
            return
        # Last live copy failed with work undelivered: re-dispatch (the
        # in-flight work of a dead replica is never lost) until the
        # patience budget runs out, then shed — still exactly one Result.
        st.requests = undone
        st.attempts += 1
        del self._jobs[ev.job.job_id]
        if st.attempts <= self.max_redispatch:
            self.redispatched += 1
            self._retry.append(st)
        else:
            now = time.monotonic()
            for r in undone:
                lat = (now - r.arrival) * 1e3
                self._done_ids.add(r.request_id)
                self.degraded_results["redispatch_exhausted"] += 1
                self._latencies_ms.append(lat)
                self._completed.append(Result(
                    r.request_id, np.empty(0, np.int32),
                    np.empty(0, np.float32), lat,
                    timed_out=lat > r.deadline_ms, shed=True,
                    degraded="redispatch_exhausted"))

    # -- health ---------------------------------------------------------

    def _strike(self, rid: int):
        rs = self.replicas[rid]
        now = time.monotonic()
        if rs.state == PROBING:
            # Half-open probe failed: back to ejected, backoff doubled.
            rs.state = EJECTED
            rs.ejected_at = now
            rs.cooldown_ms *= 2.0
            return
        rs.strikes += 1
        if rs.strikes >= self.eject_after and rs.state != EJECTED:
            rs.state = EJECTED
            rs.ejected_at = now
            rs.ejections += 1
        elif rs.strikes >= self.suspect_after and rs.state == HEALTHY:
            rs.state = SUSPECT

    def _ok(self, rid: int):
        rs = self.replicas[rid]
        if rs.state == PROBING:
            if self.mutable and (self._needs_recovery[rid]
                                 or self._lag(rid) > self.staleness_budget):
                # The probe answered, but the replica has not finished
                # replaying its missed catalogue delta: re-admission is
                # gated on catch-up.  Stay PROBING — the next probe
                # trials it again once the worker has caught up.
                return
            rs.state = HEALTHY
            rs.strikes = 0
            rs.cooldown_ms = self._base_cooldown_ms
            rs.readmissions += 1
            return
        if rs.strikes > 0:
            rs.strikes -= 1
            if rs.state == SUSPECT and rs.strikes < self.suspect_after:
                rs.state = HEALTHY

    def _update_health(self):
        now = time.monotonic()
        for rs in self.replicas:
            if rs.state == EJECTED and \
                    (now - rs.ejected_at) * 1e3 >= rs.cooldown_ms:
                rs.state = PROBING
                rs.probe_outstanding = False

    def _eligible(self, exclude: int = -1) -> Optional[int]:
        """Pick the assignable replica: a free half-open probe slot first
        (a probing replica takes at most ONE job, and re-admission can
        only happen by actually trialling it — ranking it behind healthy
        replicas would starve the probe forever on a healthy fleet),
        then healthy before suspect, least-loaded within a rank.  When
        every replica is ejected, force the one closest to cooldown into
        probing — liveness must not wait for a timer while requests hold
        deadlines."""
        rank = {PROBING: 0, HEALTHY: 1, SUSPECT: 2}
        best, best_key = None, None
        for rid, rs in enumerate(self.replicas):
            if rid == exclude or rs.state == EJECTED:
                continue
            if rs.state == PROBING and rs.probe_outstanding:
                continue
            # A replica lagging the committed catalogue past the budget
            # serves stale (tagged) answers: deprioritise it within its
            # health rank so fresh replicas absorb the traffic first —
            # but never exclude it, or a single-replica fabric would
            # deadlock against its own catch-up.
            stale = int(self.mutable
                        and self._lag(rid) > self.staleness_budget)
            key = (rank[rs.state], stale,
                   rs.inflight + self._queues[rid].qsize())
            if best_key is None or key < best_key:
                best, best_key = rid, key
        if best is None:
            ejected = [(self.replicas[rid].ejected_at
                        + self.replicas[rid].cooldown_ms / 1e3, rid)
                       for rid in range(self.n_replicas)
                       if rid != exclude
                       and self.replicas[rid].state == EJECTED]
            if ejected:
                _, rid = min(ejected)
                self.replicas[rid].state = PROBING
                self.replicas[rid].probe_outstanding = False
                return rid
        return best

    # -- assignment / hedging / ladder ----------------------------------

    def _put(self, rid: int, job: _Job):
        rs = self.replicas[rid]
        rs.dispatched += 1
        rs.inflight += 1
        if rs.state == PROBING:
            rs.probe_outstanding = True
        self._queues[rid].put(job)

    def _assign(self, st: _JobState) -> bool:
        rid = self._eligible()
        if rid is None:
            return False
        st.replica = rid
        st.first_dispatch_t = st.first_dispatch_t or time.monotonic()
        jid = self._next_job_id
        self._next_job_id += 1
        self._jobs[jid] = st
        self._put(rid, _Job(jid, st.requests, st.k_cap, st.rung_pin, rid))
        return True

    def _schedule(self):
        while self._retry:
            st = self._retry[0]
            st.copies = 1
            st.hedged = False
            if not self._assign(st):
                return                    # nothing assignable right now
            self._retry.popleft()
        while self.batcher.ready():
            reqs = self.batcher.next_batch()
            st = _JobState(reqs,
                           k_cap=(self.degrade_k_cap if self.level >= 1
                                  else None),
                           rung_pin=self.level >= 2, replica=-1)
            if not self._assign(st):
                # Put them back at the FRONT: arrival order is preserved
                # and the next pump retries.
                for r in reversed(reqs):
                    self.batcher.queue.appendleft(r)
                    self.batcher._enq_t.appendleft(r.arrival)
                return

    def hedge_delay_ms(self) -> float:
        """Current hedge trigger: observed p99 job wall time, floored —
        with few samples the floor dominates so a cold fabric does not
        hedge on compile noise."""
        if len(self._job_wall_ms) < 16:
            return self.hedge_floor_ms
        return max(self.hedge_floor_ms,
                   float(np.percentile(np.asarray(self._job_wall_ms), 99)))

    def _maybe_hedge(self):
        delay_ms = self.hedge_delay_ms()
        now = time.monotonic()
        for jid, st in list(self._jobs.items()):
            if st.hedged or st.copies != 1:
                continue
            if (now - st.first_dispatch_t) * 1e3 < delay_ms:
                continue
            rid = self._eligible(exclude=st.replica)
            if rid is None or self.replicas[rid].state != HEALTHY:
                continue                  # only hedge onto healthy spares
            st.hedged = True
            st.copies += 1
            self.hedges += 1
            self._put(rid, _Job(jid, st.requests, st.k_cap, st.rung_pin,
                                rid, hedged=True))

    def _load(self) -> int:
        return (len(self.batcher.queue)
                + sum(len(st.requests) for st in self._jobs.values())
                + sum(len(st.requests) for st in self._retry))

    def _update_load(self):
        depth = self._load()
        if depth >= self.degrade_high:
            self._over += 1
            self._under = 0
            if self._over >= self.degrade_patience and self.level < 3:
                self.level += 1
                self.degrade_events += 1
                self._over = 0
        elif depth <= self.degrade_low:
            self._under += 1
            self._over = 0
            if self._under >= self.recover_patience and self.level > 0:
                self.level -= 1
                self.recover_events += 1
                self._under = 0
        else:
            # Hysteresis band between the watermarks: hold the level.
            self._over = self._under = 0

    # -- observability ---------------------------------------------------

    def stats(self) -> Dict[str, Any]:
        lats = self._latencies_ms
        done = len(self._done_ids)
        per_replica = {}
        for rid, rs in enumerate(self.replicas):
            per_replica[rid] = {
                "state": rs.state, "strikes": rs.strikes,
                "ejections": rs.ejections, "readmissions": rs.readmissions,
                "dispatched": rs.dispatched, "completed": rs.completed,
                "failures": rs.failures, "stragglers": rs.stragglers,
                "queue_depth": self._queues[rid].qsize() + rs.inflight,
                "n_compiles": len(self.engines[rid]._compiled),
            }
            if self.mutable:
                per_replica[rid]["applied_lsn"] = self._applied_lsn[rid]
                per_replica[rid]["lag"] = self._lag(rid)
        lat = np.asarray(lats) if lats else None
        out: Dict[str, Any] = {
            "count": float(done),
            "pending": float(len(self.batcher.queue)),
            "outstanding": float(sum(len(st.requests)
                                     for st in self._jobs.values())),
            "p50_ms": float(np.percentile(lat, 50)) if lat is not None
            else None,
            "p99_ms": float(np.percentile(lat, 99)) if lat is not None
            else None,
            "hedges": float(self.hedges),
            "hedge_wins": float(self.hedge_wins),
            "hedge_delay_ms": self.hedge_delay_ms(),
            "duplicates_suppressed": float(self.duplicates_suppressed),
            "redispatched": float(self.redispatched),
            "degrade_level": self.level,
            "degrade_events": float(self.degrade_events),
            "recover_events": float(self.recover_events),
            "degraded_results": dict(self.degraded_results),
            "shed_load": float(self.shed_load),
            "replicas": per_replica,
        }
        if self.mutable:
            out.update({
                "committed_lsn": float(self._committed_lsn),
                "mutations_applied": float(self.mutations_applied),
                "stale_served": float(self.stale_served),
                "catchup_events": float(self.catchup_events),
                "staleness_budget": float(self.staleness_budget),
                "log": self.log.stats() if self.log is not None else None,
            })
        return out
