from repro.serving.catalogue_log import CatalogueLog
from repro.serving.engine import (DecodeEngine, InFlightBatch, MicroBatcher,
                                  PreparedBatch, Request, Result,
                                  RetrievalEngine)
from repro.serving.router import ReplicaRouter, ReplicaState

__all__ = ["CatalogueLog", "DecodeEngine", "InFlightBatch", "MicroBatcher",
           "PreparedBatch", "ReplicaRouter", "ReplicaState", "Request",
           "Result", "RetrievalEngine"]
