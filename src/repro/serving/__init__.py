from repro.serving.engine import (DecodeEngine, MicroBatcher, Request,
                                  Result, RetrievalEngine)

__all__ = ["DecodeEngine", "MicroBatcher", "Request", "Result",
           "RetrievalEngine"]
