"""Durable catalogue state: a checksummed mutation WAL + LSN-keyed
snapshots (ISSUE 10).

PR 7 made the catalogue mutable and PR 8 replicated the serving fabric,
but the mutation stream itself lived in one process's memory: a crash
lost the catalogue, and an ejected replica probed back in serving
whatever head it died with.  This module is the missing durability
layer:

* **Write-ahead log** — every ``("insert", row)`` / ``("delete", id)`` /
  ``("update", id, row)`` op is appended to ``wal.log`` as one
  checksummed record carrying a monotonic log sequence number (LSN,
  starting at 1).  Record layout::

      header  = <IIQ  magic, payload_len, lsn     (16 bytes)
      payload = op tag (1 byte) + operands        (rows as int16 LE)
      footer  = <I    crc32(header + payload)     (4 bytes)

  Appends are **fsync-batched**: the OS flush happens every
  ``fsync_every`` records (or on :meth:`sync`), trading a bounded
  durability window for append throughput — the classic group-commit
  knob, measured in the ``recovery`` BENCH section.

* **Torn-tail recovery** — a writer crash mid-append leaves a partial or
  checksum-broken final record.  Opening the log for writing scans from
  the start and TRUNCATES the file at the last valid record boundary
  (LSNs must also be contiguous — a record that checksums but skips a
  sequence number marks the tail as garbage).  Read-only scans stop at
  the same boundary without truncating, so replicas can tail the log
  while the writer appends.

* **LSN-keyed snapshots** — :meth:`snapshot` persists the
  ``MutableHeadState`` arrays (codes, tombstone mask, freelist order,
  slot high-water mark) through ``training.checkpoint.CheckpointManager``
  with the LSN as the step: atomic tmp-then-rename publish, per-file
  CRC32 in the manifest, keep-last-k GC.  Pruning metadata is NOT
  stored — :meth:`recover` rebuilds it exactly from codes + live, which
  by construction equals ``MutableHeadState.rebuild_oracle()`` at the
  snapshot LSN.

* **Recovery** = newest *valid* snapshot (corrupt ones are skipped via
  the hardened ``restore_latest``) + replay of the log tail in LSN
  order.  Replay through the real mutation API is deterministic (FIFO
  freelist), so the recovered catalogue is bit-identical to the
  writer's at the same LSN; ``recover(verify=True)`` additionally
  retightens and asserts bit-parity with the from-scratch oracle.

The router (``serving/router.py``) threads this log through its
replicas: ``apply_mutations`` appends before any replica applies (WAL
discipline), every ``Result`` carries the serving replica's applied-LSN
watermark, and a crashed replica recovers from here before the health
FSM may re-admit it.
"""
from __future__ import annotations

import json
import os
import struct
import zlib
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.core.mutation import MutableHeadState, apply_op
from repro.training.checkpoint import (CheckpointManager,
                                       CorruptCheckpointError)
from repro.training.fault_tolerance import SimulatedFailure

_MAGIC = 0x4C414357                      # "WCAL"
_HEADER = struct.Struct("<IIQ")          # magic, payload_len, lsn
_CRC = struct.Struct("<I")
_IID = struct.Struct("<q")

# Sanity cap on a single record's payload: one op is a tag plus at most
# one item id and one code row.  Anything bigger in a header means the
# scan ran into garbage, not a record.
_MAX_PAYLOAD = 1 << 20


def encode_op(op) -> bytes:
    """Serialise one mutation op.  Code rows are stored as int16 LE —
    wide enough for any sub-id vocabulary (b <= 32768) and independent
    of the in-memory code dtype, which the catalogue meta records."""
    kind = op[0]
    if kind == "insert":
        return b"I" + np.asarray(op[1], np.int16).tobytes()
    if kind == "delete":
        return b"D" + _IID.pack(int(op[1]))
    if kind == "update":
        return (b"U" + _IID.pack(int(op[1]))
                + np.asarray(op[2], np.int16).tobytes())
    raise ValueError(f"unknown catalogue op kind {kind!r}")


def decode_op(payload: bytes):
    tag = payload[:1]
    if tag == b"I":
        return ("insert", np.frombuffer(payload[1:], np.int16))
    if tag == b"D":
        return ("delete", _IID.unpack(payload[1:9])[0])
    if tag == b"U":
        return ("update", _IID.unpack(payload[1:9])[0],
                np.frombuffer(payload[9:], np.int16))
    raise ValueError(f"unknown op tag {tag!r}")


def _scan(path: str) -> Tuple[List[Tuple[int, int, int]], int]:
    """Walk the log's records; returns ``([(lsn, offset, end)], valid_end)``
    where ``valid_end`` is the byte offset just past the last valid
    record.  Stops — never raises — at the first torn, checksum-broken,
    or LSN-discontinuous record: everything past a crash point is dead
    weight by definition (the writer never acked it as durable)."""
    records: List[Tuple[int, int, int]] = []
    valid_end = 0
    if not os.path.exists(path):
        return records, valid_end
    prev_lsn = 0
    with open(path, "rb") as f:
        while True:
            off = f.tell()
            header = f.read(_HEADER.size)
            if len(header) < _HEADER.size:
                break                              # clean EOF or torn header
            magic, plen, lsn = _HEADER.unpack(header)
            if magic != _MAGIC or plen > _MAX_PAYLOAD:
                break                              # garbage header
            body = f.read(plen + _CRC.size)
            if len(body) < plen + _CRC.size:
                break                              # torn payload/crc
            payload, crc = body[:plen], _CRC.unpack(body[plen:])[0]
            if zlib.crc32(header + payload) != crc:
                break                              # corrupt record
            if lsn != prev_lsn + 1 and prev_lsn != 0:
                break                              # sequence gap: not ours
            prev_lsn = lsn
            valid_end = off + _HEADER.size + plen + _CRC.size
            records.append((lsn, off, valid_end))
    return records, valid_end


class CatalogueLog:
    """Append-only checksummed WAL + versioned snapshots for one mutable
    catalogue.  One writer instance per log directory; any number of
    concurrent read-only scans (:meth:`read_ops`, :meth:`recover`) — a
    reader that races an in-flight append simply stops at the last
    complete record, exactly like a post-crash scan would."""

    def __init__(self, log_dir: str, *, fsync_every: int = 32,
                 snapshot_every: int = 0, keep_snapshots: int = 3,
                 read_only: bool = False):
        self.log_dir = log_dir
        self.path = os.path.join(log_dir, "wal.log")
        self.snap_dir = os.path.join(log_dir, "snapshots")
        self.fsync_every = max(1, int(fsync_every))
        self.snapshot_every = int(snapshot_every)
        self.keep_snapshots = int(keep_snapshots)
        self.read_only = read_only
        os.makedirs(log_dir, exist_ok=True)

        records, valid_end = _scan(self.path)
        size = os.path.getsize(self.path) if os.path.exists(self.path) else 0
        self.torn_bytes_dropped = size - valid_end
        self.lsn = records[-1][0] if records else 0
        if not read_only and size > valid_end:
            # Torn tail from a writer crash: truncate to the last valid
            # record boundary so the next append extends a clean log.
            with open(self.path, "r+b") as f:
                f.truncate(valid_end)
        self._fh = None
        self._unsynced = 0
        self.n_fsyncs = 0
        self.n_appends = 0
        self._crashed = False
        # Chaos hook: appending THIS lsn writes only a partial record
        # (torn tail), fsyncs it, and raises SimulatedFailure — the
        # deterministic "writer died mid-append" experiment.
        self.fail_at_lsn: Optional[int] = None

    # -- append side ------------------------------------------------------

    def _handle(self):
        if self._fh is None:
            self._fh = open(self.path, "ab")
        return self._fh

    def append(self, op) -> int:
        """Append one op; returns its LSN.  Durability lags by up to
        ``fsync_every`` records (call :meth:`sync` to force)."""
        if self.read_only:
            raise ValueError("log opened read_only; no appends")
        if self._crashed:
            raise RuntimeError("log writer crashed mid-append; reopen the "
                               "log (torn-tail truncation) to continue")
        lsn = self.lsn + 1
        payload = encode_op(op)
        header = _HEADER.pack(_MAGIC, len(payload), lsn)
        record = header + payload + _CRC.pack(zlib.crc32(header + payload))
        fh = self._handle()
        if self.fail_at_lsn is not None and lsn == self.fail_at_lsn:
            fh.write(record[:max(1, len(record) // 2)])
            fh.flush()
            os.fsync(fh.fileno())
            self._crashed = True
            raise SimulatedFailure(
                f"catalogue log writer crashed mid-append at lsn {lsn} "
                "(torn record on disk)")
        fh.write(record)
        self.lsn = lsn
        self.n_appends += 1
        self._unsynced += 1
        if self._unsynced >= self.fsync_every:
            self.sync()
        return lsn

    def append_many(self, ops) -> List[int]:
        return [self.append(op) for op in ops]

    def sync(self):
        if self._fh is not None and self._unsynced:
            self._fh.flush()
            os.fsync(self._fh.fileno())
            self.n_fsyncs += 1
            self._unsynced = 0

    def close(self):
        if self._fh is not None:
            if not self._crashed:
                self.sync()
            self._fh.close()
            self._fh = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # -- snapshots --------------------------------------------------------

    def _meta_path(self) -> str:
        return os.path.join(self.log_dir, "meta.json")

    def _write_meta(self, mstate: MutableHeadState):
        meta = {"version": 1, "capacity": mstate.cap, "m": mstate.m,
                "b": mstate.b, "tile": mstate.tile,
                "backend": mstate.backend,
                "super_factor": mstate.super_factor,
                "code_dtype": str(np.dtype(mstate.codes.dtype))}
        existing = self.meta()
        if existing is not None:
            static = {k: existing.get(k) for k in meta}
            if static != meta:
                raise ValueError(
                    f"catalogue shape changed under the log: {static} -> "
                    f"{meta}; a capacity/layout change needs a fresh log "
                    "directory (it is a recompile boundary anyway)")
            return
        tmp = self._meta_path() + ".tmp"
        with open(tmp, "w") as f:
            json.dump(meta, f)
        os.replace(tmp, self._meta_path())

    def meta(self) -> Optional[dict]:
        try:
            with open(self._meta_path()) as f:
                return json.load(f)
        except (OSError, ValueError):
            return None

    def _snap_mgr(self) -> CheckpointManager:
        return CheckpointManager(self.snap_dir, keep=self.keep_snapshots,
                                 async_save=False)

    def snapshot(self, mstate: MutableHeadState) -> int:
        """Persist the catalogue arrays keyed by the current LSN.  The
        freelist is stored IN ORDER (padded with -1 to capacity — fixed
        shapes keep the checkpoint templates static) because FIFO reuse
        order is part of replay determinism."""
        if self.read_only:
            raise ValueError("log opened read_only; no snapshots")
        self._write_meta(mstate)
        self.sync()           # the log is never behind its snapshot
        free = np.full(mstate.cap, -1, np.int32)
        if mstate.free:
            free[:len(mstate.free)] = mstate.free
        flat = {"codes": np.asarray(mstate.codes),
                "live": np.asarray(mstate.live),
                "free": free,
                "scalars": np.asarray([mstate.n_rows, self.lsn], np.int32)}
        self._snap_mgr().save(self.lsn, {"catalogue": flat}, block=True)
        return self.lsn

    def maybe_snapshot(self, mstate: MutableHeadState) -> Optional[int]:
        """Snapshot-cadence policy: snapshot when ``snapshot_every`` ops
        have accumulated since the newest snapshot (0 disables)."""
        if self.snapshot_every <= 0:
            return None
        last = self.latest_snapshot_lsn()
        if last is not None and self.lsn - last < self.snapshot_every:
            return None
        return self.snapshot(mstate)

    def latest_snapshot_lsn(self) -> Optional[int]:
        steps = self._snap_mgr().valid_steps()
        return steps[-1] if steps else None

    # -- read / recover side ----------------------------------------------

    def read_ops(self, after: int = 0,
                 upto: Optional[int] = None) -> Iterator[Tuple[int, object]]:
        """Yield ``(lsn, op)`` for every valid record with ``after < lsn
        <= upto``.  Pure read: tolerant of a torn tail (stops), never
        truncates, safe to call while the writer appends."""
        with open(self.path, "rb") if os.path.exists(self.path) else \
                _EmptyReader() as f:
            prev_lsn = 0
            while True:
                header = f.read(_HEADER.size)
                if len(header) < _HEADER.size:
                    return
                magic, plen, lsn = _HEADER.unpack(header)
                if magic != _MAGIC or plen > _MAX_PAYLOAD:
                    return
                body = f.read(plen + _CRC.size)
                if len(body) < plen + _CRC.size:
                    return
                payload, crc = body[:plen], _CRC.unpack(body[plen:])[0]
                if zlib.crc32(header + payload) != crc:
                    return
                if lsn != prev_lsn + 1 and prev_lsn != 0:
                    return
                prev_lsn = lsn
                if upto is not None and lsn > upto:
                    return
                if lsn > after:
                    yield lsn, decode_op(payload)

    def recover(self, *, upto: Optional[int] = None,
                verify: bool = False) -> Tuple[MutableHeadState, int]:
        """Newest valid snapshot + tail replay; returns ``(state, lsn)``.

        Never raises on crash damage: a torn log tail is ignored and a
        corrupt newest snapshot falls back to the previous valid one
        (``restore_latest``) — the only hard errors are a log directory
        that never held a snapshot, or a snapshot/log pair whose static
        catalogue meta is missing.  ``verify=True`` retightens the
        replayed state and asserts bit-parity with
        ``rebuild_oracle()`` — the recovery-exactness contract."""
        meta = self.meta()
        if meta is None:
            raise CorruptCheckpointError(
                f"no catalogue meta under {self.log_dir!r}; the log was "
                "never attached to a catalogue (snapshot() writes it)")
        cap, m = meta["capacity"], meta["m"]
        dtype = np.dtype(meta["code_dtype"])
        templates = {"catalogue": {
            "codes": np.zeros((cap, m), dtype),
            "live": np.zeros((cap,), np.bool_),
            "free": np.zeros((cap,), np.int32),
            "scalars": np.zeros((2,), np.int32)}}
        mgr = self._snap_mgr()
        if upto is None:
            snap_lsn, out = mgr.restore_latest(templates)
        else:
            # Point-in-time recovery: the base snapshot must not be past
            # the fence, or replay can't wind back to it.
            snap_lsn, out = None, None
            for s in reversed([s for s in mgr.all_steps() if s <= upto]):
                if not mgr.validate_step(s):
                    continue
                try:
                    out = mgr.restore(s, templates)
                    snap_lsn = s
                    break
                except CorruptCheckpointError:
                    continue
            if snap_lsn is None:
                raise CorruptCheckpointError(
                    f"no valid snapshot at or before lsn {upto} under "
                    f"{self.snap_dir!r}")
        cat = out["catalogue"]
        scalars = np.asarray(cat["scalars"])
        n_rows, stored_lsn = int(scalars[0]), int(scalars[1])
        assert stored_lsn == snap_lsn, \
            f"snapshot step {snap_lsn} carries lsn {stored_lsn}"
        free = [int(s) for s in np.asarray(cat["free"]) if s >= 0]
        mstate = MutableHeadState.from_snapshot(
            cat["codes"], cat["live"], free, n_rows, meta["b"],
            meta["tile"], backend=meta["backend"],
            super_factor=meta["super_factor"])
        applied = snap_lsn
        for lsn, op in self.read_ops(after=snap_lsn, upto=upto):
            row_dtype = mstate.codes.dtype
            if op[0] == "insert":
                op = ("insert", np.asarray(op[1], row_dtype))
            elif op[0] == "update":
                op = ("update", op[1], np.asarray(op[2], row_dtype))
            apply_op(mstate, op)
            applied = lsn
        if verify:
            import jax
            mstate.retighten()
            got = jax.tree_util.tree_leaves(mstate.state)
            want = jax.tree_util.tree_leaves(mstate.rebuild_oracle())
            for g, w in zip(got, want):
                np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
        return mstate, applied

    # -- observability ----------------------------------------------------

    def stats(self) -> Dict[str, float]:
        size = os.path.getsize(self.path) if os.path.exists(self.path) else 0
        snaps = self._snap_mgr().valid_steps()
        return {"lsn": float(self.lsn),
                "log_bytes": float(size),
                "n_appends": float(self.n_appends),
                "n_fsyncs": float(self.n_fsyncs),
                "torn_bytes_dropped": float(self.torn_bytes_dropped),
                "n_snapshots": float(len(snaps)),
                "latest_snapshot_lsn": float(snaps[-1]) if snaps else -1.0}


class _EmptyReader:
    """Context-managed stand-in for a missing log file (fresh dir)."""

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def read(self, n: int) -> bytes:
        return b""
