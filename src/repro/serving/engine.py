"""Batched serving engine.

Two engines share the queue/batcher machinery:

* ``RetrievalEngine`` — the paper's serving mode: request = user history,
  response = top-K items.  Backbone -> phi -> PQTopK -> TopK, batched.
* ``DecodeEngine``    — LM decode with slot-based continuous batching: a
  fixed pool of KV-cache slots; requests claim a slot, every ``step()``
  decodes one token for all active slots through the PQ vocab head.

Both apply deadline-based request timeouts (serving-side straggler
mitigation, same policy knob as training's StragglerMonitor).
"""
from __future__ import annotations

import collections
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.training.fault_tolerance import (SimulatedFailure,
                                            StragglerMonitor)


@dataclass
class Request:
    request_id: int
    payload: Any                      # user seq (np.ndarray) / prompt ids
    k: int = 10
    arrival: float = field(default_factory=time.monotonic)
    # Requests past their deadline are SHED before dispatch (run_once)
    # and count as timeouts.  The default is deliberately lenient — a
    # cold engine's first dispatch compiles, which on a loaded host can
    # take seconds, and a request with no explicit latency contract
    # should be served late rather than dropped.  Pass a tight
    # deadline_ms to opt into real shedding.
    deadline_ms: float = 60_000.0


@dataclass
class Result:
    request_id: int
    items: np.ndarray
    scores: np.ndarray
    latency_ms: float
    timed_out: bool = False
    # A shed request was never scored: either it was already past its
    # deadline before dispatch (load shedding — items/scores empty), or
    # its batch exhausted the retry budget after injected/real failures,
    # or the router's degradation ladder dropped it under overload.
    shed: bool = False
    # Exactness contract (docs/SERVING.md): every step of the router's
    # load-degradation ladder that can change what the client receives is
    # tagged here ("k_cap", "k_cap+rung_pin", "load_shed", ...).  An empty
    # tag on a non-shed result asserts the full exact serving path ran —
    # the chaos harness holds those results bit-identical to the
    # single-engine oracle.
    degraded: str = ""
    # Which replica served this result (-1: single-engine / shed before
    # dispatch) and whether it was raced against a hedge re-issue.
    replica: int = -1
    hedged: bool = False
    # Catalogue version watermark (durable-mutation routing, ISSUE 10):
    # the serving replica's applied LSN at dispatch time, or -1 when the
    # fabric serves an immutable catalogue.  A result whose replica
    # lagged the committed LSN past the router's staleness budget also
    # carries degraded="stale_catalogue".
    lsn: int = -1


class MicroBatcher:
    """Greedy size/timeout batcher with power-of-two padding buckets so jit
    recompiles stay bounded.

    ``max_wait_ms`` is the partial-batch dispatch deadline: a batch is
    ``ready`` once it is full OR its oldest enqueued request has waited
    longer than ``max_wait_ms`` — the pipelined router loop polls
    :meth:`ready` so a trickle of requests dispatches after the wait
    expires instead of blocking on a full bucket (the synchronous
    ``drain`` path always flushes, so it never waits)."""

    def __init__(self, max_batch: int = 64, max_wait_ms: float = 2.0):
        self.max_batch = max_batch
        self.max_wait_ms = max_wait_ms
        self.queue: collections.deque[Request] = collections.deque()
        self._enq_t: collections.deque[float] = collections.deque()

    def submit(self, req: Request):
        self.queue.append(req)
        self._enq_t.append(time.monotonic())

    def oldest_wait_ms(self, now: Optional[float] = None) -> float:
        """How long the head-of-queue request has been waiting (0.0 when
        the queue is empty)."""
        if not self._enq_t:
            return 0.0
        return ((time.monotonic() if now is None else now)
                - self._enq_t[0]) * 1e3

    def ready(self, now: Optional[float] = None) -> bool:
        """True when a batch should dispatch: full bucket, or the oldest
        request has out-waited ``max_wait_ms``."""
        if len(self.queue) >= self.max_batch:
            return True
        return bool(self.queue) and self.oldest_wait_ms(now) >= self.max_wait_ms

    def next_batch(self) -> List[Request]:
        out = []
        while self.queue and len(out) < self.max_batch:
            out.append(self.queue.popleft())
            if self._enq_t:
                self._enq_t.popleft()
        return out

    @staticmethod
    def bucket(n: int, max_batch: int) -> int:
        b = 1
        while b < n:
            b *= 2
        return min(b, max_batch)


@dataclass
class PreparedBatch:
    """Host-side work of one dispatch, done: deadline-shed applied (twice
    — once on entry and once more after the variant compile, so a request
    whose deadline expired *during* a cold-start AOT compile is shed, not
    served late), requests padded into their pow2 bucket, the compiled
    variant resolved.  ``launch`` turns this into device work."""
    requests: List[Request]           # alive, in batch-row order
    seqs: Any                         # (bucket, seq_len) jnp.int32
    fn: Callable                      # compiled variant (takes seqs)
    kk: int                           # trace-static batch k
    batch_index: int
    degraded: str = ""


@dataclass
class InFlightBatch:
    """One asynchronously dispatched batch: the device owns ``out`` until
    :meth:`RetrievalEngine.complete` blocks on it.  The pipelined router
    loop keeps up to ``dispatch_depth`` of these per replica in flight
    while the host pads/dispatches the next batch."""
    prep: PreparedBatch
    out: Any
    t0: float
    straggler: bool = False           # set by complete()


class RetrievalEngine:
    """Paper-mode serving: top-K item retrieval for user sequences."""

    def __init__(self, serve_fn: Callable[[jax.Array, int], Tuple[jax.Array, jax.Array]],
                 *, seq_len: int, k: int = 10, max_k: Optional[int] = None,
                 max_batch: int = 64, method: Optional[str] = None,
                 jit_serve: bool = True, ladder: Optional[Tuple[int, ...]] = None,
                 head_state: Optional[Any] = None,
                 faults: Optional[Any] = None, max_retries: int = 2,
                 retry_backoff_ms: float = 1.0,
                 straggler_factor: float = 3.0,
                 serve_fn_pinned: Optional[Callable] = None):
        """``serve_fn(item_seq (B,S) int32, k)`` -> (ids (B,k), scores).

        ``method`` is informational here (the scoring route is baked into
        ``serve_fn``); use :meth:`for_seqrec` to have the engine build the
        serve function for a named route itself.  Every built-in route —
        including the single-dispatch ``pqtopk_pruned`` cascade — is a pure
        traced function, so ``jit_serve=True`` is the norm; pass ``False``
        only for externally supplied serve functions that manage their own
        dispatch.

        Compiled serve variants are memoised per ``(batch_bucket, k_bucket,
        method)`` (AOT ``lower().compile()`` for jitted routes), and the
        variant count is surfaced as ``stats()["n_compiles"]`` so recompile
        behaviour is observable and regression-testable.

        ``max_k`` caps client-supplied ``Request.k`` — oversized k must not
        reach ``serve_fn`` (the fused kernel rejects k > tile, and any
        route fails at k > N), where it would abort every request in the
        batch.  Callers raising it above ``k`` are asserting that
        ``serve_fn`` can serve up to ``max_k`` winners (i.e. max_k <=
        min(N, kernel tile) for the baked-in route — :meth:`for_seqrec`
        derives this bound itself); the default is ``k``, which is always
        safe because ``serve_fn`` must support the engine's own k.

        ``ladder`` records the calibrated slot-budget ladder baked into a
        pruned ``serve_fn`` (informational; :meth:`for_seqrec` calibrates
        and sets it).  A ladder-enabled serve fn returns a third output —
        the rung taken — which the engine tallies into ``rung_counts`` so
        ``stats()["rung_hit_fraction"]`` reports how often serving stayed
        on a non-exhaustive rung.

        ``head_state`` makes the engine **hot-swappable**: ``serve_fn``
        then takes a third argument — a pytree of head arrays (codes,
        pruned metadata, tombstone mask) — which the engine threads as
        *data* into every dispatch and :meth:`swap_head_state` replaces
        between batches.  Compiled variants close over ``self`` and read
        the head late, so a swap with identical structure/shapes/dtypes
        costs ZERO recompiles — that invariant is what makes streaming
        catalogue mutation servable (docs/PRUNING.md §Catalogue
        mutation).

        ``faults`` (a ``ServeFaultInjector``) plus ``max_retries`` /
        ``retry_backoff_ms`` give :meth:`run_once` graceful degradation:
        a failed dispatch retries with exponential backoff, exhausted
        retries shed the batch (``Result.shed``) instead of crashing, and
        already-expired requests are shed before padding/dispatch.  A
        ``StragglerMonitor`` (``straggler_factor`` x rolling median)
        flags slow batches into ``stats()["stragglers"]``.

        ``serve_fn_pinned`` is the optional *degraded* serve route the
        router's load ladder steps down to (``rung_pin``): same signature
        as ``serve_fn``, typically the pruned cascade pinned to its
        cheapest calibrated rung (bounded cost, possibly inexact — every
        result served through it is tagged ``Result.degraded``).
        Compiled variants are memoised separately per (bucket, k, method,
        pinned) key.
        """
        self._serve_fn = serve_fn
        self._jit_serve = jit_serve
        self._fn = (jax.jit(serve_fn, static_argnums=(1,)) if jit_serve
                    else serve_fn)
        self._serve_fn_pinned = serve_fn_pinned
        self._fn_pinned = None
        if serve_fn_pinned is not None:
            self._fn_pinned = (jax.jit(serve_fn_pinned, static_argnums=(1,))
                               if jit_serve else serve_fn_pinned)
        self._compiled: Dict[Tuple[int, int, Optional[str]], Callable] = {}
        self.seq_len = seq_len
        self.k = k
        self.max_k = k if max_k is None else max(max_k, k)
        self.method = method
        self.ladder = None if ladder is None else tuple(ladder)
        self.rung_counts: collections.Counter = collections.Counter()
        self.batcher = MicroBatcher(max_batch=max_batch)
        self.latencies_ms: List[float] = []
        self.timeouts = 0
        self._head_state = head_state
        self._head_treedef = None
        self._head_sds = None
        if head_state is not None:
            self._head_treedef = jax.tree_util.tree_structure(head_state)
            self._head_sds = jax.tree_util.tree_map(
                lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), head_state)
        self.faults = faults
        self.max_retries = max_retries
        self.retry_backoff_ms = retry_backoff_ms
        self.straggler_monitor = StragglerMonitor(factor=straggler_factor)
        self.retried = 0
        self.shed = 0
        self.n_swaps = 0
        self._batch_index = 0

    @classmethod
    def for_seqrec(cls, params, cfg, *, k: int = 10, max_batch: int = 64,
                   method: Optional[str] = None, sharded_mesh=None,
                   calibrate: Optional[bool] = None,
                   survival_stats: Optional[Sequence[int]] = None,
                   ladder: Optional[Tuple[int, ...]] = None,
                   faults: Optional[Any] = None,
                   max_retries: int = 2,
                   retry_backoff_ms: float = 1.0,
                   ) -> "RetrievalEngine":
        """Stand up an engine on a seqrec model with an explicit scoring
        route.  ``method=None`` falls back to ``cfg.serve_method`` — the
        production configs default to ``"pqtopk_fused"`` (the Pallas fused
        score+top-k kernel).  ``method="pqtopk_pruned"`` is the
        single-dispatch in-graph cascade: backbone, bounds, theta seeding,
        survivor compaction and compacted scoring all trace into ONE jitted
        serve function — no host sync anywhere on the serve path.

        For the pruned route the engine also installs a **calibrated
        slot-budget ladder**: a one-shot calibration pass at build time
        (``calibrate``, default on; or recorded ``survival_stats`` — a
        sequence of surviving-tile counts from production traffic) feeds
        ``pruning.calibrate_ladder``, and the resulting 2-3 rung ladder of
        power-of-two budgets is baked into the serve fn.  The common case
        then scores a small compacted buffer, overflow escalates rung by
        rung inside the same dispatch, and the final rung is always
        exhaustive — exactness at any skew.  An explicit ``ladder`` skips
        calibration entirely; ``calibrate=False`` disables the ladder.

        When ``cfg.pq.query_grouping`` is on, calibration is
        **group-aware**: the observable is the MAX per-group survivor
        count (``pruning.survival_count_grouped``) rather than the
        batch-any union count, because that is what the grouped ladder
        escalates on — union-count rungs would be needlessly tall and
        forfeit most of the per-group win.
        """
        from repro.core import pruning, retrieval_head
        from repro.kernels.pqtopk import kernel as pqtopk_kernel
        from repro.models import seqrec as seqrec_lib
        method = method or getattr(cfg, "serve_method", "pqtopk")
        # Largest k any route built here can serve: bounded by the
        # catalogue, and for the fused-kernel-backed routes also by the
        # kernel's item tile (pq_topk / pq_topk_tiles reject k > tile).
        max_k = cfg.n_items
        if method in ("pqtopk_fused", "pqtopk_pruned"):
            max_k = min(max_k, pqtopk_kernel.DEFAULT_TILE)

        if method == "pqtopk_pruned" and sharded_mesh is not None:
            # Align the pruning-tile layout to the mesh ONCE at engine
            # build, so the sharded cascade never rebuilds metadata.
            params = {**params, "item_emb":
                      retrieval_head.ensure_sharded_pruned_state(
                          params["item_emb"], sharded_mesh, k_hint=max_k)}

        if method == "pqtopk_pruned" and ladder is None \
                and calibrate is not False:
            state = params["item_emb"].get("pruned") \
                if retrieval_head.is_pq(params["item_emb"]) else None
            if isinstance(state, pruning.PrunedHeadState):
                counts = (list(survival_stats)
                          if survival_stats is not None else
                          cls._observe_survival(params, cfg, k=k,
                                                max_batch=max_batch))
                # Sharded states tile per shard: calibrate rungs against
                # the per-shard tile count the sharded cascade compacts.
                t = (state.tiles_per_shard if state.shards > 1
                     else state.n_tiles)
                counts = [c if state.shards <= 1 else -(-c // state.shards)
                          for c in counts]
                ladder = pruning.calibrate_ladder(counts, t, k, state.tile)

        with_rung = method == "pqtopk_pruned" and ladder is not None

        def serve_fn(seqs, kk):
            return seqrec_lib.serve_topk(params, seqs, cfg, k=kk,
                                         method=method,
                                         sharded_mesh=sharded_mesh,
                                         ladder=ladder,
                                         return_rung=with_rung)

        # Degraded route for the router's load ladder: the same cascade
        # pinned to its cheapest calibrated rung (no exhaustive
        # escalation — bounded cost, possibly inexact, so every result
        # served through it is tagged).  Only built when the ladder has a
        # genuinely non-exhaustive rung: a one-tile catalogue's pinned
        # rung IS the exhaustive rung and would degrade nothing.
        serve_fn_pinned = None
        if method == "pqtopk_pruned" and ladder is not None \
                and sharded_mesh is None:
            state = params["item_emb"].get("pruned") \
                if retrieval_head.is_pq(params["item_emb"]) else None
            n_tiles = getattr(state, "n_tiles", None)
            if n_tiles is None or min(ladder) < n_tiles:
                def serve_fn_pinned(seqs, kk):
                    return seqrec_lib.serve_topk(params, seqs, cfg, k=kk,
                                                 method=method,
                                                 ladder=ladder,
                                                 pin_rung=True)

        return cls(serve_fn, seq_len=cfg.max_seq_len, k=k, max_k=max_k,
                   max_batch=max_batch, method=method, ladder=ladder,
                   faults=faults, max_retries=max_retries,
                   retry_backoff_ms=retry_backoff_ms,
                   serve_fn_pinned=serve_fn_pinned)

    @classmethod
    def for_seqrec_mutable(cls, params, cfg, mstate, *, k: int = 10,
                           max_batch: int = 64,
                           calibrate: Optional[bool] = None,
                           survival_stats: Optional[Sequence[int]] = None,
                           ladder: Optional[Tuple[int, ...]] = None,
                           faults: Optional[Any] = None,
                           max_retries: int = 2,
                           retry_backoff_ms: float = 1.0,
                           ) -> "RetrievalEngine":
        """Engine over a **mutable catalogue**: serve the single-dispatch
        pruned cascade against a ``mutation.MutableHeadState`` whose
        codes / bounds / tombstone mask are threaded through every
        dispatch as data and hot-swapped between batches with
        :meth:`swap_head_state` — zero recompiles per mutation because
        the pow2-padded capacity keeps every shape static.

        The serve fn merges the swapped head arrays over ``params``'s
        item head: ``codes`` (capacity rows), the incrementally
        maintained ``pruned`` state (bounds may be stale after deletes —
        still dominating, hence still exact), and ``live`` (the
        tombstone mask the cascade's theta seeding and kernel both
        honour, so delisted items can never surface).  Calibration runs
        against the initial head with the mask threaded through
        ``pruning.survival_count``.
        """
        from repro.core import pruning
        from repro.kernels.pqtopk import kernel as pqtopk_kernel
        from repro.models import seqrec as seqrec_lib
        head0 = mstate.head_arrays() if hasattr(mstate, "head_arrays") \
            else dict(mstate)
        max_k = min(cfg.n_items, pqtopk_kernel.DEFAULT_TILE)

        def merged(head):
            return {**params, "item_emb": {**params["item_emb"],
                                           "codes": head["codes"],
                                           "pruned": head["pruned"],
                                           "live": head["live"]}}

        if ladder is None and calibrate is not False:
            counts = (list(survival_stats)
                      if survival_stats is not None else
                      cls._observe_survival(merged(head0), cfg, k=k,
                                            max_batch=max_batch))
            state = head0["pruned"]
            ladder = pruning.calibrate_ladder(counts, state.n_tiles, k,
                                              state.tile)
        with_rung = ladder is not None

        def serve_fn(seqs, kk, head):
            return seqrec_lib.serve_topk(merged(head), seqs, cfg, k=kk,
                                         method="pqtopk_pruned",
                                         ladder=ladder,
                                         return_rung=with_rung)

        return cls(serve_fn, seq_len=cfg.max_seq_len, k=k, max_k=max_k,
                   max_batch=max_batch, method="pqtopk_pruned",
                   ladder=ladder, head_state=head0, faults=faults,
                   max_retries=max_retries,
                   retry_backoff_ms=retry_backoff_ms)

    @staticmethod
    def _observe_survival(params, cfg, *, k: int, max_batch: int,
                          n_batches: int = 3, seed: int = 0) -> List[int]:
        """One-shot build-time calibration pass: surviving-tile counts of
        the pruned cascade's bounds+theta prefix (no scoring) over a few
        synthetic request batches at representative batch sizes.  Survival
        uses the batch-any rule, so small and full batches bracket the
        counts serving will see.  Production deployments can skip this by
        recording real counts and passing ``survival_stats``."""
        from repro.core import pruning, retrieval_head, scoring
        from repro.models import seqrec as seqrec_lib
        head = params["item_emb"]
        state = head["pruned"]
        seed_kw = retrieval_head._seed_kwargs(getattr(cfg, "pq", None))

        pq = getattr(cfg, "pq", None)
        grouped = pq is not None and pq.query_grouping and pq.n_groups > 1

        def count_fn(seqs):
            phi = seqrec_lib.sequence_embedding(params, seqs, cfg)
            s = scoring.subid_scores(head["sub_emb"].astype(jnp.float32),
                                     phi.astype(jnp.float32))
            st = state
            if state.shards > 1:
                # Flat counts from a per-shard layout would misread tile
                # boundaries; bound each shard's tile block independently
                # (same layout the sharded cascade sees) and sum.
                st = pruning.build_pruned_state(
                    head["codes"], state.b, state.tile,
                    backend=state.backend)
            live = head.get("live")
            if grouped:
                # Group-aware observable: the grouped ladder escalates on
                # the max per-group count, so calibrate against that.
                return pruning.survival_count_grouped(
                    head["codes"], s, k, st, n_groups=pq.n_groups,
                    live=live, **seed_kw)
            return pruning.survival_count(head["codes"], s, k, st,
                                          live=live, **seed_kw)

        fn = jax.jit(count_fn)
        rng = np.random.default_rng(seed)
        counts = []
        for bsz in dict.fromkeys((1, min(8, max_batch), max_batch)):
            for _ in range(n_batches):
                seqs = rng.integers(
                    1, cfg.n_items + 1,
                    (bsz, cfg.max_seq_len)).astype(np.int32)
                counts.append(int(fn(jnp.asarray(seqs))))
        return counts

    def submit(self, req: Request):
        self.batcher.submit(req)

    def batch_k(self, ks: Sequence[int]) -> int:
        """The trace-static k this engine compiles for a batch whose client
        ks are ``ks``: each clamped into [1, max_k] (an unvalidated
        oversized k would abort the whole batch inside serve_fn), floored
        at the engine's own k, then bucketed to a power of two so distinct
        client values cannot drive unbounded jit recompiles — same policy
        as the batch-size padding buckets.  Factored out of
        :meth:`run_once` so the recompile-hazard analysis pass
        (``repro.analysis.passes.recompile``) probes the real mapping that
        keys compiled variants, not a re-implementation of it."""
        kk = max(max(min(int(k), self.max_k) for k in ks), self.k, 1)
        return MicroBatcher.bucket(kk, self.max_k)

    def _variant(self, bucket: int, kk: int, pinned: bool = False) -> Callable:
        """Memoised serve variant for one (batch_bucket, k_bucket, method,
        pinned) key.

        Jitted routes are AOT-lowered and compiled once per key, so
        ``stats()["n_compiles"]`` counts real compilations — the padding
        buckets guarantee the key space is O(log(max_batch) * log(max_k)).
        Returned callables take the (bucketed) sequence batch only.
        ``pinned=True`` resolves against the degraded rung-pinned serve
        route (``serve_fn_pinned``); callers must fall back to
        ``pinned=False`` when :attr:`has_pinned` is unset.
        """
        if pinned and self._fn_pinned is None:
            raise ValueError("no pinned (degraded) serve fn on this engine")
        key = (bucket, kk, self.method, pinned)
        fn = self._compiled.get(key)
        if fn is None:
            jfn = self._fn_pinned if pinned else self._fn
            sfn = self._serve_fn_pinned if pinned else self._serve_fn
            if self._jit_serve:
                sds = jax.ShapeDtypeStruct((bucket, self.seq_len), jnp.int32)
                try:
                    if self._head_state is not None:
                        # Head arrays are DATA: lower against their
                        # shapes/dtypes once, read ``self._head_state``
                        # late at every call so swap_head_state takes
                        # effect with zero recompiles.
                        exe = jfn.lower(sds, kk, self._head_sds).compile()
                        fn = lambda seqs, _e=exe: _e(seqs, self._head_state)
                    else:
                        exe = jfn.lower(sds, kk).compile()
                        fn = lambda seqs, _e=exe: _e(seqs)
                except (jax.errors.TracerArrayConversionError,
                        jax.errors.TracerBoolConversionError,
                        jax.errors.ConcretizationTypeError):
                    # Unlowerable serve fn (caller-supplied closure doing
                    # host work): fall back to jit's dispatch cache — the
                    # key still counts one logical compile per variant.
                    # Genuine compile failures (OOM, lowering bugs) are NOT
                    # swallowed: they raise here, before any request of the
                    # batch is half-served, and never inflate n_compiles.
                    if self._head_state is not None:
                        fn = lambda seqs, _k=kk, _f=jfn: _f(
                            seqs, _k, self._head_state)
                    else:
                        fn = lambda seqs, _k=kk, _f=jfn: _f(seqs, _k)
            elif self._head_state is not None:
                fn = lambda seqs, _k=kk, _f=sfn: _f(
                    seqs, _k, self._head_state)
            else:
                fn = lambda seqs, _k=kk, _f=sfn: _f(seqs, _k)
            self._compiled[key] = fn
        return fn

    @property
    def has_pinned(self) -> bool:
        """Whether this engine carries a degraded rung-pinned serve route
        (the router's ladder step 2 falls back to step 1 without one)."""
        return self._fn_pinned is not None

    def swap_head_state(self, head) -> None:
        """Replace the served head arrays between batches — zero recompiles.

        Accepts either the pytree ``head_arrays()`` returns or any object
        exposing that method (e.g. ``mutation.MutableHeadState``).  The
        swap is validated structurally: the pytree treedef (which carries
        the pruned state's static metadata — tile, capacity, backend) and
        every leaf's shape/dtype must match what the engine compiled
        against, because those are baked into the AOT executables.  The
        pow2-capacity design in ``core.mutation`` exists precisely so
        live churn never trips this check; a capacity *growth* must build
        a new engine (a new compile is then honest and expected)."""
        if self._head_state is None:
            raise ValueError(
                "engine was not built with a swappable head; use "
                "for_seqrec_mutable (or pass head_state=) to enable "
                "hot swapping")
        if hasattr(head, "head_arrays"):
            head = head.head_arrays()
        leaves, treedef = jax.tree_util.tree_flatten(head)
        if treedef != self._head_treedef:
            raise ValueError(
                f"swapped head structure {treedef} differs from the "
                f"compiled structure {self._head_treedef}; hot swap "
                "requires identical static metadata")
        for old, new in zip(jax.tree_util.tree_leaves(self._head_sds),
                            leaves):
            if old.shape != new.shape or old.dtype != new.dtype:
                raise ValueError(
                    f"hot swap would change a head leaf from "
                    f"{old.shape}/{old.dtype} to {new.shape}/{new.dtype}; "
                    "capacity and dtypes are compile-static — rebuild the "
                    "engine to grow the catalogue")
        self._head_state = jax.tree_util.tree_unflatten(treedef, leaves)
        self.n_swaps += 1

    def _shed_result(self, r: Request, now: float,
                     degraded: str = "") -> Result:
        lat = (now - r.arrival) * 1e3
        timed_out = lat > r.deadline_ms
        self.shed += 1
        self.timeouts += int(timed_out)
        self.latencies_ms.append(lat)
        return Result(r.request_id, np.empty(0, np.int32),
                      np.empty(0, np.float32), lat, timed_out=timed_out,
                      shed=True, degraded=degraded)

    def prepare(self, reqs: List[Request], *, k_cap: Optional[int] = None,
                rung_pin: bool = False,
                ) -> Tuple[List[Result], Optional[PreparedBatch]]:
        """Host side of one dispatch: shed expired requests, pad the rest
        into their pow2 bucket, resolve (and if cold, compile) the serve
        variant.  Returns (shed results, prepared batch or None).

        ``k_cap``/``rung_pin`` are the router's degradation-ladder knobs:
        cap the batch k below the clients' asks, and/or route through the
        rung-pinned serve fn.  Both are recorded in
        ``PreparedBatch.degraded`` so every result carries its tag.

        Deadline shedding runs TWICE: once on entry, and once more after
        the variant lookup — a cold engine's first lookup AOT-compiles,
        which can take seconds, and a tight-deadline request that expired
        *during* that compile must come back ``timed_out`` instead of
        being served late.  The second pass keeps the already-compiled
        bucket (expired rows just become padding), so the compile is not
        wasted and later identical requests serve normally.
        """
        batch_index = self._batch_index
        self._batch_index += 1
        # Load shedding BEFORE padding/dispatch: a request already past
        # its deadline would burn a batch slot producing an answer nobody
        # is waiting for — and worse, widen the padding bucket for the
        # requests that are still alive.
        now = time.monotonic()
        results: List[Result] = []
        alive: List[Request] = []
        for r in reqs:
            if (now - r.arrival) * 1e3 > r.deadline_ms:
                results.append(self._shed_result(r, now))
            else:
                alive.append(r)
        if not alive:
            return results, None
        bucket = MicroBatcher.bucket(len(alive), self.batcher.max_batch)
        # Requests in one batch may disagree on k: score once at the batch
        # max and slice each request's prefix — top-k prefixes nest, so
        # every request sees exactly its own top-k.  batch_k clamps and
        # buckets so client values cannot drive unbounded recompiles.
        kk = self.batch_k([r.k for r in alive])
        tags = []
        if k_cap is not None:
            capped = MicroBatcher.bucket(max(1, min(k_cap, self.max_k)),
                                         self.max_k)
            if capped < kk:
                kk = capped
                tags.append("k_cap")
        pinned = rung_pin and self.has_pinned
        if pinned:
            tags.append("rung_pin")
        fn = self._variant(bucket, kk, pinned=pinned)
        # Post-compile re-shed (same bucket — expired rows become padding).
        now = time.monotonic()
        survivors: List[Request] = []
        for r in alive:
            if (now - r.arrival) * 1e3 > r.deadline_ms:
                results.append(self._shed_result(r, now))
            else:
                survivors.append(r)
        if not survivors:
            return results, None
        seqs = np.zeros((bucket, self.seq_len), np.int32)
        for i, r in enumerate(survivors):
            s = np.asarray(r.payload)[-self.seq_len:]
            seqs[i, -len(s):] = s
        return results, PreparedBatch(survivors, jnp.asarray(seqs), fn, kk,
                                      batch_index, degraded="+".join(tags))

    def launch(self, prep: PreparedBatch) -> InFlightBatch:
        """Dispatch a prepared batch asynchronously.  The returned handle's
        ``out`` is an in-flight device computation — the caller overlaps
        host work (padding the NEXT batch) with it and calls
        :meth:`complete` to block.  Injected faults
        (``ServeFaultInjector.check``) raise here, before dispatch, so
        the caller's retry loop sees them."""
        if self.faults is not None:
            self.faults.check(prep.batch_index)
        t0 = time.monotonic()
        return InFlightBatch(prep, prep.fn(prep.seqs), t0)

    def complete(self, inflight: InFlightBatch) -> List[Result]:
        """Block until the dispatched batch has actually finished, then
        timestamp and slice per-request results.

        ``jax.block_until_ready`` comes FIRST: JAX dispatch is async even
        on CPU, so timestamping after the ``fn(seqs)`` call alone would
        measure enqueue cost, not completion — latency accounting and the
        straggler monitor would both read near-zero for a slow kernel."""
        prep = inflight.prep
        out = jax.block_until_ready(inflight.out)
        if self.faults is not None:
            delay = self.faults.delay_s(prep.batch_index)
            if delay:
                time.sleep(delay)  # synthetic straggler, lands in elapsed
        now = time.monotonic()
        inflight.straggler = self.straggler_monitor.record(
            prep.batch_index, now - inflight.t0)
        if len(out) == 3:
            # Ladder-enabled pruned route: third output is the rung taken
            # (an i32 scalar riding the same dispatch) — tally it so
            # stats() can report rung_hit_fraction.
            ids, scores, rung = out
            self.rung_counts[int(rung)] += 1
        else:
            ids, scores = out
        ids, scores = np.asarray(ids), np.asarray(scores)
        results: List[Result] = []
        for i, r in enumerate(prep.requests):
            lat = (now - r.arrival) * 1e3
            timed_out = lat > r.deadline_ms
            self.timeouts += int(timed_out)
            self.latencies_ms.append(lat)
            rk = max(1, min(r.k, prep.kk))
            results.append(Result(r.request_id, ids[i, :rk],
                                  scores[i, :rk], lat, timed_out,
                                  degraded=prep.degraded))
        return results

    def run_once(self, *, k_cap: Optional[int] = None,
                 rung_pin: bool = False) -> List[Result]:
        """Synchronous serve of one batch: prepare -> launch (with bounded
        retry) -> complete.  The pipelined router loop uses the pieces
        directly to keep multiple batches in flight."""
        reqs = self.batcher.next_batch()
        if not reqs:
            return []
        results, prep = self.prepare(reqs, k_cap=k_cap, rung_pin=rung_pin)
        if prep is None:
            return results
        # Bounded retry with exponential backoff: only *injected/declared*
        # failures (SimulatedFailure) are retried — they model transient
        # node faults.  Genuine serve bugs still raise.  Exhausted retries
        # shed the batch instead of crashing the serving loop.
        inflight = None
        for attempt in range(self.max_retries + 1):
            try:
                inflight = self.launch(prep)
                break
            except SimulatedFailure:
                if attempt >= self.max_retries:
                    break
                self.retried += 1
                time.sleep(self.retry_backoff_ms * (2 ** attempt) / 1e3)
        if inflight is None:
            # Retries exhausted: the batch never dispatched, so the
            # injector's straggler delay must NOT fire — sleeping here
            # would only inflate the shed requests' recorded latency.
            now = time.monotonic()
            results.extend(self._shed_result(r, now) for r in prep.requests)
            return results
        results.extend(self.complete(inflight))
        return results

    def drain(self) -> List[Result]:
        out = []
        while self.batcher.queue:
            out.extend(self.run_once())
        return out

    def stats(self) -> Dict[str, Any]:
        # No traffic yet -> None, NOT 0.0: a placeholder zero is a real
        # latency to any aggregator averaging across replicas and would
        # drag fleet percentiles toward zero.
        lat = (np.asarray(self.latencies_ms) if self.latencies_ms
               else None)
        out: Dict[str, Any] = {
            "count": float(len(self.latencies_ms)),
            "mRT_ms": float(np.median(lat)) if lat is not None else None,
            "p99_ms": (float(np.percentile(lat, 99))
                       if lat is not None else None),
            "timeouts": float(self.timeouts),
            "n_compiles": float(len(self._compiled)),
            "retried": float(self.retried),
            "shed": float(self.shed),
            "stragglers": float(len(self.straggler_monitor.flagged)),
        }
        if self._head_state is not None:
            out["n_swaps"] = float(self.n_swaps)
        if self.ladder is not None:
            # Fraction of served batches that stayed on a non-exhaustive
            # rung (the last rung of the normalised ladder scores every
            # tile); per-rung batch counts for the curious.
            total = sum(self.rung_counts.values())
            non_exhaustive = sum(c for r, c in self.rung_counts.items()
                                 if r < len(self.ladder) - 1)
            out["ladder"] = self.ladder
            out["rung_hit_fraction"] = (non_exhaustive / total if total
                                        else 0.0)
            out["rung_counts"] = dict(sorted(self.rung_counts.items()))
        return out


class DecodeEngine:
    """Slot-based continuous batching for LM decode."""

    def __init__(self, decode_fn, init_caches_fn, *, n_slots: int,
                 max_len: int, k: int = 8):
        """``decode_fn(tokens (B,), pos (B,), caches)`` ->
        (next_tokens (B,), caches); caches batched over slots."""
        self._decode = jax.jit(decode_fn)
        self.caches = init_caches_fn(n_slots)
        self.n_slots = n_slots
        self.max_len = max_len
        self.k = k
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.slot_pos = np.zeros(n_slots, np.int32)
        self.slot_token = np.zeros(n_slots, np.int32)
        self.slot_out: List[List[int]] = [[] for _ in range(n_slots)]
        self.waiting: collections.deque[Request] = collections.deque()
        self.finished: List[Tuple[Request, List[int]]] = []

    def submit(self, req: Request):
        self.waiting.append(req)

    def _admit(self):
        for s in range(self.n_slots):
            if self.slot_req[s] is None and self.waiting:
                req = self.waiting.popleft()
                self.slot_req[s] = req
                self.slot_pos[s] = 0
                self.slot_token[s] = int(np.asarray(req.payload).reshape(-1)[0])
                self.slot_out[s] = []

    def step(self, max_new: int = 16):
        """One engine iteration: admit, decode one token for all slots,
        retire finished requests."""
        self._admit()
        active = [s for s in range(self.n_slots) if self.slot_req[s]]
        if not active:
            return
        tokens = jnp.asarray(self.slot_token)
        pos = jnp.asarray(self.slot_pos)
        nxt, self.caches = self._decode(tokens, pos, self.caches)
        nxt = np.asarray(nxt)
        for s in active:
            self.slot_out[s].append(int(nxt[s]))
            self.slot_token[s] = int(nxt[s])
            self.slot_pos[s] += 1
            if self.slot_pos[s] >= min(max_new, self.max_len - 1):
                self.finished.append((self.slot_req[s], self.slot_out[s]))
                self.slot_req[s] = None

    def run(self, max_new: int = 16):
        while self.waiting or any(self.slot_req):
            self.step(max_new)
        return self.finished
