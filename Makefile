.PHONY: test bench serve

test:
	bash scripts/ci.sh

bench:
	PYTHONPATH=src python -m benchmarks.run

serve:
	PYTHONPATH=src python -m repro.launch.serve --reduced --method pqtopk_fused
