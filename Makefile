.PHONY: test test-fast bench serve

test:
	bash scripts/ci.sh

# Fast tier only: everything not marked slow / sharded / hypothesis
# (markers registered in pytest.ini).  The full matrix runs in `make test`.
test-fast:
	PYTHONPATH=src python -m pytest -q -m "not slow and not sharded and not hypothesis"

bench:
	PYTHONPATH=src python -m benchmarks.run

serve:
	PYTHONPATH=src python -m repro.launch.serve --reduced --method pqtopk_fused
